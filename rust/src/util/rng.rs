//! Deterministic PRNG (SplitMix64) with the distribution helpers the
//! testbed needs: uniforms, normals (Box–Muller), shuffles and categorical
//! draws. Deterministic seeding keeps every experiment reproducible —
//! identical seeds produce identical synthetic datasets, identical batch
//! schedules and identical virtual-time traces.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (stable across calls with same label).
    pub fn fork(&self, label: u64) -> Rng {
        let mut child = Rng::new(self.state ^ label.wrapping_mul(0xBF58476D1CE4E5B9));
        child.next_u64();
        child
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let mut a2 = root.fork(1);
        assert_eq!(a.next_u64(), a2.next_u64());
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
