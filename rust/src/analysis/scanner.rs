//! Comment/string-aware line scanner for the invariant auditor.
//!
//! Rules match *tokens in code*, so a file is first split into a per-line
//! **code view** (comments and the contents of string/char literals blanked
//! out) and a per-line **comment view** (only comment text, which is where
//! `audit:allow` annotations live). The split is a small lexer state
//! machine, not a parser: it understands line comments, nested block
//! comments, plain and raw strings (`r"…"`, `r#"…"#`, byte variants), char
//! literals, and the char-literal vs lifetime ambiguity. That is enough
//! to keep pattern strings inside the rule definitions themselves — or an
//! unordered container mentioned in a doc comment — from ever matching.
//!
//! The scanner is ported line-for-line in `python/tools/audit.py`; the two
//! must stay byte-equivalent (the CI audit job compares full reports).

/// One source line split into its code and comment parts.
#[derive(Debug, Clone, Default)]
pub struct ScanLine {
    /// Line text with comments and literal contents removed.
    pub code: String,
    /// Comment text on the line (including the `//` / `/*` markers).
    pub comment: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Normal,
    LineComment,
    BlockComment,
    Str,
    RawStr,
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Split `src` into per-line code and comment views.
pub fn scan(src: &str) -> Vec<ScanLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = ScanLine::default();
    let mut state = State::Normal;
    let mut depth = 0usize; // block-comment nesting
    let mut raw_hashes = 0usize; // '#' count of the open raw string
    let mut escaped = false; // inside Str/CharLit, previous char was '\'
    let mut prev_code = ' '; // last code char seen (raw-string lookbehind)
    let mut i = 0usize;
    let n = chars.len();
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if state == State::LineComment {
                state = State::Normal;
            }
            escaped = false;
            prev_code = ' ';
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let next = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '/' && next == '/' {
                    state = State::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = State::BlockComment;
                    depth = 1;
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur.code.push(' ');
                    prev_code = ' ';
                    i += 1;
                } else if (c == 'r' || (c == 'b' && next == 'r')) && !is_ident(prev_code) {
                    // Possible raw string: (r|br) '#'* '"'
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        cur.code.push(' ');
                        state = State::RawStr;
                        raw_hashes = h;
                        prev_code = ' ';
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        prev_code = c;
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: a backslash or a closing
                    // quote two chars on means a literal; otherwise it is
                    // a lifetime and scanning just continues.
                    let next2 = if i + 2 < n { chars[i + 2] } else { '\0' };
                    if next == '\\' {
                        state = State::CharLit;
                        escaped = true;
                        cur.code.push(' ');
                        prev_code = ' ';
                        i += 2;
                    } else if next2 == '\'' && next != '\'' {
                        cur.code.push_str("   ");
                        prev_code = ' ';
                        i += 3;
                    } else {
                        cur.code.push(' ');
                        prev_code = ' ';
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    prev_code = c;
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment => {
                let next = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '/' && next == '*' {
                    depth += 1;
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '*' && next == '/' {
                    depth -= 1;
                    cur.comment.push_str("*/");
                    i += 2;
                    if depth == 0 {
                        state = State::Normal;
                    }
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if escaped {
                    escaped = false;
                } else if c == '\\' {
                    escaped = true;
                } else if c == '"' {
                    state = State::Normal;
                }
                i += 1;
            }
            State::RawStr => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..raw_hashes {
                        if i + 1 + k >= n || chars[i + 1 + k] != '#' {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        state = State::Normal;
                        i += 1 + raw_hashes;
                        continue;
                    }
                }
                i += 1;
            }
            State::CharLit => {
                if escaped {
                    escaped = false;
                } else if c == '\\' {
                    escaped = true;
                } else if c == '\'' {
                    state = State::Normal;
                }
                i += 1;
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code(src: &str) -> Vec<String> {
        scan(src).into_iter().map(|l| l.code).collect()
    }

    fn comment(src: &str) -> Vec<String> {
        scan(src).into_iter().map(|l| l.comment).collect()
    }

    #[test]
    fn line_comments_leave_code_view() {
        let c = code("let x = 1; // uses HashMap\nlet y = 2;\n");
        assert_eq!(c[0], "let x = 1; ");
        assert_eq!(c[1], "let y = 2;");
        let m = comment("let x = 1; // uses HashMap\n");
        assert_eq!(m[0], "// uses HashMap");
    }

    #[test]
    fn nested_block_comments_are_stripped() {
        let src = "a /* outer /* inner */ still */ b\n";
        assert_eq!(code(src)[0], "a  b");
        assert!(comment(src)[0].contains("inner"));
    }

    #[test]
    fn block_comment_spans_lines() {
        let c = code("before /* HashMap\nHashSet */ after\n");
        assert_eq!(c[0], "before ");
        assert_eq!(c[1], " after");
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = code("let s = \"Instant::now\"; call();\n");
        assert_eq!(c[0], "let s =  ; call();");
    }

    #[test]
    fn escaped_quote_does_not_close_string() {
        let c = code("let s = \"a\\\"HashMap\"; tail\n");
        assert_eq!(c[0], "let s =  ; tail");
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = code("let s = r#\"EventKind:: \"quoted\" \"#; x\n");
        assert_eq!(c[0], "let s =  ; x");
    }

    #[test]
    fn identifier_ending_in_r_is_not_raw_string() {
        let c = code("let var = attr\"\";\n");
        // `attr` keeps its final r; the plain string after it is blanked.
        assert_eq!(c[0], "let var = attr ;");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = code("let c = 'x'; fn f<'a>(v: &'a str) {}\n");
        assert_eq!(c[0], "let c =    ; fn f< a>(v: & a str) {}");
        let c = code("let nl = '\\n'; let q = '\\'';\n");
        assert_eq!(c[0], "let nl =  ; let q =  ;");
    }

    #[test]
    fn multiline_string_keeps_line_count() {
        let src = "let s = \"first\nsecond HashMap\"; after\n";
        let lines = scan(src);
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].code, "let s =  ");
        assert_eq!(lines[1].code, "; after");
    }

    #[test]
    fn allow_text_lands_in_comment_view_only() {
        let src = "use std::collections::BTreeMap; // audit:allow(x, y)\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains("audit:allow"));
        assert!(lines[0].comment.contains("audit:allow(x, y)"));
    }

    #[test]
    fn trailing_line_without_newline_is_kept() {
        let lines = scan("let a = 1;");
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].code, "let a = 1;");
    }
}
