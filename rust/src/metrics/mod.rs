//! Cost ledger, communication stats and per-stage timing.
//!
//! Every substrate charges money into a [`Ledger`] and bytes into
//! [`CommStats`]; the training loop charges stage durations into a
//! [`StageTimer`]. Reports are rendered from these three accumulators —
//! they are the testbed's measurement plane, matching the paper's metrics
//! (§3.1: training time & cost per epoch, communication overhead, accuracy).

use std::collections::BTreeMap;
use std::fmt;

/// What a dollar was spent on (AWS line items).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CostKind {
    /// Lambda GB-seconds + request fees.
    LambdaCompute,
    /// EC2 GPU instance hours.
    Ec2Gpu,
    /// EC2 instance hours hosting Redis/RedisAI (excluded from the paper's
    /// cost model; tracked separately and reported off to the side).
    Ec2Redis,
    /// S3 PUT/GET request fees.
    S3Requests,
    /// SQS/RabbitMQ message fees.
    QueueMessages,
    /// Step Functions state transitions.
    StepFnTransitions,
}

impl fmt::Display for CostKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            CostKind::LambdaCompute => "lambda-compute",
            CostKind::Ec2Gpu => "ec2-gpu",
            CostKind::Ec2Redis => "ec2-redis",
            CostKind::S3Requests => "s3-requests",
            CostKind::QueueMessages => "queue-messages",
            CostKind::StepFnTransitions => "stepfn-transitions",
        };
        f.write_str(s)
    }
}

/// Accumulates USD per cost kind.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    items: BTreeMap<CostKind, f64>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    pub fn charge(&mut self, kind: CostKind, usd: f64) {
        debug_assert!(usd.is_finite() && usd >= 0.0, "bad charge {usd}");
        *self.items.entry(kind).or_insert(0.0) += usd;
    }

    pub fn get(&self, kind: CostKind) -> f64 {
        self.items.get(&kind).copied().unwrap_or(0.0)
    }

    /// Total following the paper's cost model (Ec2Redis excluded — the
    /// paper deems database hosting negligible and excludes it; §5 Threats).
    pub fn total_paper(&self) -> f64 {
        self.items
            .iter()
            .filter(|(k, _)| **k != CostKind::Ec2Redis)
            .map(|(_, v)| v)
            .sum()
    }

    /// Total including everything.
    pub fn total_full(&self) -> f64 {
        self.items.values().sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (CostKind, f64)> + '_ {
        self.items.iter().map(|(k, v)| (*k, *v))
    }

    pub fn merge(&mut self, other: &Ledger) {
        for (k, v) in other.iter() {
            self.charge(k, v);
        }
    }
}

/// Classification of a communication operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CommKind {
    /// Write to shared storage (S3 put / Redis set).
    Put,
    /// Read from shared storage (S3 get / Redis get).
    Get,
    /// Queue publish.
    Publish,
    /// Queue poll/receive.
    Poll,
    /// In-database tensor op (bytes stayed inside the DB).
    InDb,
}

/// Byte/op counters per communication kind.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    ops: BTreeMap<CommKind, u64>,
    bytes: BTreeMap<CommKind, u64>,
    /// Seconds spent blocked on communication (sync stage time). Excludes
    /// producer-visibility waits, which are stalls on the *writer*, not
    /// transfer overhead — those accumulate in [`CommStats::visibility_wait`].
    pub comm_time: f64,
    /// Seconds a reader spent waiting for a key's producer to finish
    /// writing before its own transfer could start (Redis `get` paths).
    /// Separated from `comm_time` so sync stall and wire overhead are
    /// distinguishable in reports.
    pub visibility_wait: f64,
    /// Contributions skipped by the bounded-staleness sync policy (an
    /// async-mode worker proceeded without them; see
    /// `coordinator::protocol::SyncMode`). Always 0 in BSP mode. Counted
    /// per *gather decision*, whose granularity differs by topology —
    /// AllReduce/MLLess/GPU decide once per round, ScatterReduce once per
    /// chunk owner per round, SPIRT once per fetching worker per epoch —
    /// so compare the counter across modes or worker counts *within* one
    /// framework, not between frameworks.
    pub stale_skips: u64,
}

impl CommStats {
    pub fn new() -> CommStats {
        CommStats::default()
    }

    pub fn record(&mut self, kind: CommKind, bytes: u64) {
        *self.ops.entry(kind).or_insert(0) += 1;
        *self.bytes.entry(kind).or_insert(0) += bytes;
    }

    pub fn ops(&self, kind: CommKind) -> u64 {
        self.ops.get(&kind).copied().unwrap_or(0)
    }

    pub fn bytes(&self, kind: CommKind) -> u64 {
        self.bytes.get(&kind).copied().unwrap_or(0)
    }

    /// Bytes that crossed the network (everything except in-DB ops).
    pub fn wire_bytes(&self) -> u64 {
        self.bytes
            .iter()
            .filter(|(k, _)| **k != CommKind::InDb)
            .map(|(_, v)| v)
            .sum()
    }

    pub fn total_ops(&self) -> u64 {
        self.ops.values().sum()
    }

    pub fn merge(&mut self, other: &CommStats) {
        for (k, v) in &other.ops {
            *self.ops.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &other.bytes {
            *self.bytes.entry(*k).or_insert(0) += v;
        }
        self.comm_time += other.comm_time;
        self.visibility_wait += other.visibility_wait;
        self.stale_skips += other.stale_skips;
    }
}

/// What the fault-injection engine did and what it cost — the measurement
/// plane of the resilience experiments (`faults`, `exp::table4_faults`).
/// Substrate/recovery code increments these alongside the normal [`Ledger`]
/// charges so "cost of recovery" is reportable separately from base cost.
#[derive(Debug, Clone, Default)]
pub struct RecoveryStats {
    /// Lambda invocations retried after a crash (billed again).
    pub invocation_retries: u64,
    /// Cold starts paid by crash restarts (compute- or sync-phase).
    pub cold_restarts: u64,
    /// Model-state restores from a Redis snapshot after a restart.
    pub snapshot_restores: u64,
    /// Bytes moved by snapshot restores.
    pub restore_bytes: u64,
    /// Extra storage GETs issued while peers re-polled for late objects.
    pub storage_repolls: u64,
    /// Extra queue polls issued while peers re-polled for late messages.
    pub queue_repolls: u64,
    /// MLLess supervisor restarts.
    pub supervisor_restarts: u64,
    /// SPIRT P2P fetches rerouted around a down peer.
    pub rerouted_fetches: u64,
    /// Store-tier shards taken down by an injected `ShardCrash` (each
    /// restarts after a provisioning delay).
    pub shard_restarts: u64,
    /// Reads served by a replica because the primary shard was down.
    pub shard_failovers: u64,
    /// Updates dropped by injected message loss.
    pub dropped_updates: u64,
    /// Gradients corrupted by injected poisoning.
    pub poisoned_grads: u64,
    /// Spot preemptions reclaiming in-flight invocations (each recovers
    /// like a compute crash and is also counted in `invocation_retries`).
    pub preemptions: u64,
    /// Straggler-inflated compute seconds (extra over the fault-free time).
    pub straggler_secs: f64,
    /// Virtual seconds workers spent cut off by network partitions
    /// (protocol ops deferred to the heal time).
    pub partition_secs: f64,
    /// Total downtime injected by crashes (virtual seconds).
    pub downtime_secs: f64,
    /// USD charged specifically for recovery actions (subset of the ledger).
    pub cost_usd: f64,
}

impl RecoveryStats {
    pub fn new() -> RecoveryStats {
        RecoveryStats::default()
    }

    /// One-line human summary of everything that fired (`-` when nothing
    /// did) — the `Recovery` column of the resilience reports.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if self.invocation_retries > 0 {
            parts.push(format!("{} retried", self.invocation_retries));
        }
        if self.supervisor_restarts > 0 {
            parts.push(format!("{} sup restart", self.supervisor_restarts));
        }
        if self.snapshot_restores > 0 {
            parts.push(format!("{} restored", self.snapshot_restores));
        }
        if self.rerouted_fetches > 0 {
            parts.push(format!("{} rerouted", self.rerouted_fetches));
        }
        if self.shard_restarts > 0 {
            parts.push(format!("{} shard down", self.shard_restarts));
        }
        if self.shard_failovers > 0 {
            parts.push(format!("{} failover", self.shard_failovers));
        }
        if self.dropped_updates > 0 {
            parts.push(format!("{} dropped", self.dropped_updates));
        }
        if self.poisoned_grads > 0 {
            parts.push(format!("{} poisoned", self.poisoned_grads));
        }
        if self.preemptions > 0 {
            parts.push(format!("{} preempted", self.preemptions));
        }
        if self.straggler_secs > 0.0 {
            parts.push(format!("+{:.0}s straggle", self.straggler_secs));
        }
        if self.partition_secs > 0.0 {
            parts.push(format!("{:.0}s partitioned", self.partition_secs));
        }
        if self.downtime_secs > 0.0 {
            parts.push(format!("{:.1}s down", self.downtime_secs));
        }
        if parts.is_empty() {
            "-".into()
        } else {
            parts.join(", ")
        }
    }

    /// Any fault fired or any recovery action was taken.
    pub fn any(&self) -> bool {
        self.invocation_retries
            + self.cold_restarts
            + self.snapshot_restores
            + self.supervisor_restarts
            + self.rerouted_fetches
            + self.shard_restarts
            + self.shard_failovers
            + self.dropped_updates
            + self.poisoned_grads
            + self.preemptions
            > 0
            || self.straggler_secs > 0.0
            || self.partition_secs > 0.0
            || self.downtime_secs > 0.0
    }

    pub fn merge(&mut self, other: &RecoveryStats) {
        self.invocation_retries += other.invocation_retries;
        self.cold_restarts += other.cold_restarts;
        self.snapshot_restores += other.snapshot_restores;
        self.restore_bytes += other.restore_bytes;
        self.storage_repolls += other.storage_repolls;
        self.queue_repolls += other.queue_repolls;
        self.supervisor_restarts += other.supervisor_restarts;
        self.rerouted_fetches += other.rerouted_fetches;
        self.shard_restarts += other.shard_restarts;
        self.shard_failovers += other.shard_failovers;
        self.dropped_updates += other.dropped_updates;
        self.poisoned_grads += other.poisoned_grads;
        self.preemptions += other.preemptions;
        self.straggler_secs += other.straggler_secs;
        self.partition_secs += other.partition_secs;
        self.downtime_secs += other.downtime_secs;
        self.cost_usd += other.cost_usd;
    }
}

/// A latency sample set with nearest-rank percentiles — the backing store
/// for the trace layer's per-op-kind p50/p95/p99 tables
/// (`trace::histogram`). Samples are kept raw (no bucketing) so percentiles
/// are exact and deterministic.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn add(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile: the smallest sample v such that at least
    /// `p`% of samples are ≤ v (rank `ceil(p/100·n)`, clamped to [1, n]).
    /// Returns 0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        sorted[rank.clamp(1, n) - 1]
    }
}

/// The paper's Table-1 training stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    FetchDataset,
    ComputeGradients,
    Synchronize,
    ModelUpdate,
}

impl Stage {
    pub const ALL: [Stage; 4] = [
        Stage::FetchDataset,
        Stage::ComputeGradients,
        Stage::Synchronize,
        Stage::ModelUpdate,
    ];
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Stage::FetchDataset => "fetch-dataset",
            Stage::ComputeGradients => "compute-gradients",
            Stage::Synchronize => "synchronize",
            Stage::ModelUpdate => "model-update",
        };
        f.write_str(s)
    }
}

/// Virtual seconds accumulated per training stage.
#[derive(Debug, Clone, Default)]
pub struct StageTimer {
    secs: BTreeMap<Stage, f64>,
}

impl StageTimer {
    pub fn new() -> StageTimer {
        StageTimer::default()
    }

    pub fn add(&mut self, stage: Stage, secs: f64) {
        debug_assert!(secs >= 0.0, "negative stage time");
        *self.secs.entry(stage).or_insert(0.0) += secs;
    }

    pub fn get(&self, stage: Stage) -> f64 {
        self.secs.get(&stage).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.secs.values().sum()
    }

    pub fn merge(&mut self, other: &StageTimer) {
        for (k, v) in &other.secs {
            self.add(*k, *v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_excludes_redis() {
        let mut l = Ledger::new();
        l.charge(CostKind::LambdaCompute, 0.01);
        l.charge(CostKind::LambdaCompute, 0.02);
        l.charge(CostKind::Ec2Redis, 0.50);
        assert!((l.get(CostKind::LambdaCompute) - 0.03).abs() < 1e-12);
        assert!((l.total_paper() - 0.03).abs() < 1e-12);
        assert!((l.total_full() - 0.53).abs() < 1e-12);
    }

    #[test]
    fn ledger_merge() {
        let mut a = Ledger::new();
        a.charge(CostKind::S3Requests, 0.1);
        let mut b = Ledger::new();
        b.charge(CostKind::S3Requests, 0.2);
        b.charge(CostKind::Ec2Gpu, 1.0);
        a.merge(&b);
        assert!((a.get(CostKind::S3Requests) - 0.3).abs() < 1e-12);
        assert!((a.get(CostKind::Ec2Gpu) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn comm_stats_wire_bytes_exclude_indb() {
        let mut c = CommStats::new();
        c.record(CommKind::Put, 100);
        c.record(CommKind::Get, 50);
        c.record(CommKind::InDb, 10_000);
        assert_eq!(c.wire_bytes(), 150);
        assert_eq!(c.total_ops(), 3);
        assert_eq!(c.bytes(CommKind::InDb), 10_000);
    }

    #[test]
    fn recovery_summary_lists_fired_parts_only() {
        let mut r = RecoveryStats::new();
        assert_eq!(r.summary(), "-");
        r.invocation_retries = 1;
        r.downtime_secs = 2.5;
        assert_eq!(r.summary(), "1 retried, 2.5s down");
    }

    #[test]
    fn recovery_stats_merge_and_any() {
        let mut a = RecoveryStats::new();
        assert!(!a.any());
        a.invocation_retries = 2;
        a.cost_usd = 0.01;
        let mut b = RecoveryStats::new();
        b.downtime_secs = 5.0;
        b.rerouted_fetches = 1;
        a.merge(&b);
        assert!(a.any());
        assert_eq!(a.invocation_retries, 2);
        assert_eq!(a.rerouted_fetches, 1);
        assert!((a.downtime_secs - 5.0).abs() < 1e-12);
        assert!((a.cost_usd - 0.01).abs() < 1e-12);
    }

    #[test]
    fn histogram_nearest_rank() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.add(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert_eq!(h.percentile(50.0), 50.0);
        assert_eq!(h.percentile(95.0), 95.0);
        assert_eq!(h.percentile(99.0), 99.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0, "rank clamps to the smallest sample");
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.total(), 5050.0);

        // Nearest-rank on a tiny set: p50 of [10, 20] is the 1st sample.
        let mut small = Histogram::new();
        small.add(20.0);
        small.add(10.0);
        assert_eq!(small.percentile(50.0), 10.0);
        assert_eq!(small.percentile(51.0), 20.0);

        assert_eq!(Histogram::new().percentile(99.0), 0.0);
    }

    #[test]
    fn stage_timer() {
        let mut t = StageTimer::new();
        t.add(Stage::ComputeGradients, 5.0);
        t.add(Stage::Synchronize, 2.0);
        t.add(Stage::ComputeGradients, 1.0);
        assert_eq!(t.get(Stage::ComputeGradients), 6.0);
        assert_eq!(t.total(), 8.0);
    }
}
