//! Queueing resources: shared services with limited parallelism.
//!
//! A `Resource` models a service endpoint (a Redis instance, the S3 frontend
//! per prefix, a queue broker, the AllReduce master's NIC) as `c` servers.
//! A request arriving at `t` with service time `s` is placed at the earliest
//! feasible slot at or after `t` across the servers — including *backfill*
//! into idle gaps left by already-scheduled later work, so results do not
//! depend on the (arbitrary) order in which the simulation code happens to
//! issue requests for concurrent workers. That order-independence is exact
//! when the competing requests are exchangeable — equal service times,
//! arrivals on a common grid, the shape same-payload protocol rounds
//! produce (locked in by `prop_resource_backfill_is_issue_order_independent`);
//! with heterogeneous durations greedy backfill is only approximately
//! order-free. Queueing delay under contention (e.g. 16 workers hitting
//! the AllReduce master) *emerges* rather than being hand-modeled.
//!
//! Busy intervals are kept in a per-server `BTreeMap` ordered by start time,
//! so placing a request is `O(log n + g)` where `g` is the number of
//! intervals at or after the arrival (usually a handful) — not a scan of the
//! server's entire history. That matters at scale-sweep sizes: a 256-worker
//! ScatterReduce epoch issues millions of requests against one store
//! frontend, which the previous `Vec` scan made quadratic.

use std::collections::BTreeMap;

use super::vtime::VTime;

/// Outcome of scheduling one request on a resource.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Served {
    /// When service actually began (>= arrival; the gap is queueing delay).
    pub start: VTime,
    /// When service completed.
    pub end: VTime,
}

impl Served {
    pub fn queueing_delay(&self, arrival: VTime) -> f64 {
        self.start - arrival
    }
}

/// A `c`-server resource with gap-aware (backfill) scheduling.
#[derive(Debug, Clone)]
pub struct Resource {
    name: String,
    /// Per-server busy intervals, keyed by start time (values are ends).
    /// Intervals are disjoint, so they are ordered by end time as well.
    servers: Vec<BTreeMap<VTime, VTime>>,
    busy_time: f64,
    requests: u64,
}

impl Resource {
    pub fn new(name: impl Into<String>, servers: usize) -> Resource {
        assert!(servers > 0, "resource needs at least one server");
        Resource {
            name: name.into(),
            servers: vec![BTreeMap::new(); servers],
            busy_time: 0.0,
            requests: 0,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Earliest feasible start on one server for a request `(arrival, dur)`.
    ///
    /// Intervals ending at or before the arrival can neither host the
    /// request nor push it later, so the scan starts at the interval
    /// containing the arrival (if any) and walks forward from there —
    /// semantically identical to scanning the full history.
    fn earliest_on(intervals: &BTreeMap<VTime, VTime>, arrival: VTime, dur: f64) -> VTime {
        let mut candidate = arrival;
        let mut from = candidate;
        if let Some((&s, &e)) = intervals.range(..=candidate).next_back() {
            if e > candidate {
                from = s;
            }
        }
        for (&s, &e) in intervals.range(from..) {
            if candidate + dur <= s {
                return candidate; // fits in the gap before this interval
            }
            if e > candidate {
                candidate = e;
            }
        }
        candidate
    }

    /// Schedule a request arriving at `arrival` needing `service` seconds.
    pub fn serve(&mut self, arrival: VTime, service: f64) -> Served {
        let (idx, start) = self
            .servers
            .iter()
            .enumerate()
            .map(|(i, iv)| (i, Self::earliest_on(iv, arrival, service)))
            .min_by(|a, b| a.1.cmp(&b.1))
            .expect("non-empty");
        let end = start + service;
        // Distinct requests can only collide on a start key when one of the
        // intervals is zero-length (zero service time); absorbing it into
        // the longer interval preserves the busy timeline.
        let slot = self.servers[idx].entry(start).or_insert(end);
        if *slot < end {
            *slot = end;
        }
        self.busy_time += service;
        self.requests += 1;
        Served { start, end }
    }

    /// Total service time accumulated (utilization numerator).
    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Reset server availability (new experiment, same stats lifetime).
    pub fn reset(&mut self) {
        for s in &mut self.servers {
            s.clear();
        }
        self.busy_time = 0.0;
        self.requests = 0;
    }

    /// Drop busy intervals that ended at or before `before`.
    ///
    /// Semantics-preserving for any caller whose future arrivals are all
    /// `>= before`: `earliest_on` never consults an interval ending at or
    /// before the arrival (it "can neither host the request nor push it
    /// later"), so pruning them changes no placement — it only bounds the
    /// history's memory. `ClusterEnv` calls this at epoch boundaries with
    /// the minimum worker clock as the watermark (clocks never rewind past
    /// an epoch boundary), which is what keeps a 4096-worker ScatterReduce
    /// sweep — hundreds of millions of store requests — in bounded memory.
    /// Accumulated `busy_time`/`requests` stats are untouched.
    pub fn release(&mut self, before: VTime) {
        for s in &mut self.servers {
            s.retain(|_, end| *end > before);
        }
    }

    /// Busy intervals currently retained across all servers (memory gauge;
    /// `release` exists to keep this bounded per epoch).
    pub fn retained_intervals(&self) -> usize {
        self.servers.iter().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_server_serializes() {
        let mut r = Resource::new("redis", 1);
        let a = r.serve(VTime::ZERO, 2.0);
        let b = r.serve(VTime::ZERO, 3.0);
        assert_eq!(a.end, VTime::from_secs(2.0));
        assert_eq!(b.start, VTime::from_secs(2.0)); // queued behind a
        assert_eq!(b.end, VTime::from_secs(5.0));
        assert_eq!(b.queueing_delay(VTime::ZERO), 2.0);
    }

    #[test]
    fn multi_server_parallelizes() {
        let mut r = Resource::new("s3", 2);
        let a = r.serve(VTime::ZERO, 2.0);
        let b = r.serve(VTime::ZERO, 2.0);
        let c = r.serve(VTime::ZERO, 2.0);
        assert_eq!(a.end.secs(), 2.0);
        assert_eq!(b.end.secs(), 2.0); // second server
        assert_eq!(c.start.secs(), 2.0); // queued
        assert_eq!(c.end.secs(), 4.0);
    }

    #[test]
    fn backfills_idle_gaps() {
        // A later-called request with an earlier arrival must use the idle
        // gap, not queue behind already-scheduled future work.
        let mut r = Resource::new("s3", 1);
        let late = r.serve(VTime::from_secs(10.0), 1.0); // scheduled first
        assert_eq!(late.start.secs(), 10.0);
        let early = r.serve(VTime::ZERO, 1.0); // called second, arrives first
        assert_eq!(early.start.secs(), 0.0, "must backfill the [0,10) gap");
        // A request that does not fit the remaining gap goes after.
        let mid = r.serve(VTime::from_secs(9.5), 1.0);
        assert_eq!(mid.start.secs(), 11.0);
    }

    #[test]
    fn gap_too_small_is_skipped() {
        let mut r = Resource::new("x", 1);
        r.serve(VTime::ZERO, 1.0); // [0,1)
        r.serve(VTime::from_secs(1.5), 1.0); // [1.5,2.5)
        // 1.0-second job arriving at 0.8: gap [1,1.5) too small -> at 2.5.
        let s = r.serve(VTime::from_secs(0.8), 1.0);
        assert_eq!(s.start.secs(), 2.5);
        // 0.4-second job arriving at 0.9 fits the [1,1.5) gap.
        let t = r.serve(VTime::from_secs(0.9), 0.4);
        assert_eq!(t.start.secs(), 1.0);
    }

    #[test]
    fn idle_gap_not_counted_busy() {
        let mut r = Resource::new("q", 1);
        r.serve(VTime::ZERO, 1.0);
        r.serve(VTime::from_secs(10.0), 1.0);
        assert_eq!(r.busy_time(), 2.0);
        assert_eq!(r.requests(), 2);
    }

    #[test]
    fn later_arrival_not_started_early() {
        let mut r = Resource::new("x", 1);
        let s = r.serve(VTime::from_secs(5.0), 1.0);
        assert_eq!(s.start.secs(), 5.0);
        assert_eq!(s.end.secs(), 6.0);
    }

    #[test]
    fn reset_clears_schedule() {
        let mut r = Resource::new("x", 1);
        r.serve(VTime::ZERO, 5.0);
        r.reset();
        let s = r.serve(VTime::ZERO, 1.0);
        assert_eq!(s.start, VTime::ZERO);
    }

    #[test]
    fn order_insensitive_for_concurrent_workers() {
        // 4 workers x 4 requests, issued worker-major vs round-robin, must
        // produce the same per-request completion times.
        let issue = |order: &[(usize, f64)]| -> Vec<f64> {
            let mut r = Resource::new("x", 2);
            let mut ends: Vec<f64> = order
                .iter()
                .map(|&(_tag, arr)| r.serve(VTime::from_secs(arr), 1.0).end.secs())
                .collect();
            ends.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ends
        };
        let worker_major: Vec<(usize, f64)> =
            (0..4).flat_map(|w| (0..4).map(move |i| (w, i as f64))).collect();
        let round_robin: Vec<(usize, f64)> =
            (0..4).flat_map(|i| (0..4).map(move |w| (w, i as f64))).collect();
        assert_eq!(issue(&worker_major), issue(&round_robin));
    }

    #[test]
    fn release_preserves_placements_for_future_arrivals() {
        // Two identical resources, one pruned at a watermark: every request
        // arriving at or after the watermark must land bit-identically.
        let mut full = Resource::new("x", 2);
        let mut pruned = Resource::new("x", 2);
        for i in 0..200 {
            let arr = VTime::from_secs((i % 50) as f64);
            let dur = 0.25 + (i % 4) as f64 * 0.5; // heterogeneous services
            full.serve(arr, dur);
            pruned.serve(arr, dur);
        }
        let watermark = VTime::from_secs(60.0);
        pruned.release(watermark);
        assert!(pruned.retained_intervals() < full.retained_intervals());
        for i in 0..100 {
            let arr = watermark + (i % 7) as f64;
            let dur = 0.1 + (i % 3) as f64;
            let a = full.serve(arr, dur);
            let b = pruned.serve(arr, dur);
            assert_eq!(a.start.to_bits(), b.start.to_bits(), "req {i} start");
            assert_eq!(a.end.to_bits(), b.end.to_bits(), "req {i} end");
        }
    }

    #[test]
    fn release_keeps_intervals_straddling_the_watermark() {
        // An interval that started before but ends after the watermark is
        // still load: it must survive and still push later arrivals.
        let mut r = Resource::new("x", 1);
        r.serve(VTime::ZERO, 10.0); // [0, 10)
        r.release(VTime::from_secs(5.0));
        assert_eq!(r.retained_intervals(), 1);
        let s = r.serve(VTime::from_secs(5.0), 1.0);
        assert_eq!(s.start.secs(), 10.0, "straddling interval still queues");
        // Pruning exactly at an interval end drops it (end <= watermark can
        // neither host nor push a request arriving at the watermark).
        r.release(VTime::from_secs(11.0));
        assert_eq!(r.retained_intervals(), 0);
        assert_eq!(r.requests(), 2, "stats survive pruning");
        assert_eq!(r.busy_time(), 11.0);
    }

    #[test]
    fn deep_history_placement_stays_exact() {
        // Fill a long busy history, then check a backfill and an append
        // still land exactly where the linear-scan semantics put them.
        let mut r = Resource::new("x", 1);
        for i in 0..1000 {
            r.serve(VTime::from_secs(i as f64 * 2.0), 1.0); // [2i, 2i+1)
        }
        // Fits the gap [1, 2).
        let gap = r.serve(VTime::from_secs(0.5), 0.5);
        assert_eq!(gap.start.secs(), 1.0);
        // Too long for any 1-second gap: goes after the last interval.
        let tail = r.serve(VTime::from_secs(0.0), 1.5);
        assert_eq!(tail.start.secs(), 1999.0);
    }
}
