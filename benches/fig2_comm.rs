//! Bench: regenerate Fig. 2 (AllReduce vs ScatterReduce communication time
//! over 4–16 workers, MobileNet + ResNet-50 payloads).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let points = slsgpu::exp::fig2::run(&[4, 8, 12, 16]).expect("fig2");
    print!("{}", slsgpu::exp::fig2::render(&points));
    println!("regenerated in {:.0} ms", t0.elapsed().as_secs_f64() * 1000.0);
}
